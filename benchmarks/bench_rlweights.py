"""Table 5: RL weight transfer at Kimi-K2 scale (1T params).

256 training GPUs (bf16, FSDP) -> 128 inference GPUs (fp8).  Uses synthetic
(timing-only) writes — 1 TB of payload is pointless to materialise — while
the schedule itself is the real planner output and the pipeline is the real
``rlweights`` engine: watermark-bounded chunked staging, window-coalesced
WrBatches, two-phase commit.  Baseline: rank0 gather+broadcast, the pattern
of existing RL frameworks (paper: 10-100 s).

Emits Table-5-style rows — p2p vs rank0, full vs delta, EFA vs CX7 — and a
``BENCH_rlweights.json`` summary into the bench output dir for
perf-trajectory tracking across PRs.

Env knobs:
  BENCH_RL_SMOKE=1    shrink the cluster ~8x for CI bench-smoke
  BENCH_RL_COMPARE=1  also run the pre-PR per-route submission path
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

import numpy as np

from repro.core import Fabric
from repro.rlweights.planner import ParamMeta, compute_routing, schedule_stats
from repro.rlweights.transfer import (MIN_CHUNK_BYTES, CommitGate,
                                      OnlineChunkTuner, arm_commit_gates,
                                      commit_imm, data_imm, plan_chunks,
                                      resolve_chunk_bytes,
                                      run_pipelined_update)

from .obs_hooks import (TRACE, assert_no_flags, attach_health, finish_trace,
                        maybe_tracer)

# pipeline stage rates calibrated to Table 5 (Kimi-K2, 256 ranks)
H2D_GBPS = 43.0        # 8 GB/rank in 184 ms
PREP_GBPS = 15.5       # full_tensor+fuse+quantise: 8 GB in ~520 ms
INFER_TP = 8
QUANT = 0.5            # bf16 -> fp8
STAGE_SCALE = 1.0 / QUANT   # staged input bytes per wire byte

SMOKE = os.environ.get("BENCH_RL_SMOKE") == "1"
if SMOKE:
    N_TRAIN, N_INFER, N_PARAMS = 32, 16, 8
    TOTAL_PARAMS = 1.04e12 / 64
else:
    N_TRAIN, N_INFER, N_PARAMS = 256, 128, 61
    TOTAL_PARAMS = 1.04e12      # Kimi-K2

# staging memory bound per training rank; the smoke cluster stages ~1 GiB
# per rank, so smoke shrinks the bound too — with headroom for every chunk
# the staging queue would stay empty and the online-calibration rows (which
# merge the *queued* tail) would have nothing to act on
WATERMARK = (2 << 30) if not SMOKE else (256 << 20)
CHUNK = 32 << 20       # legacy static chunk knob (kept as the compare row)
DIRTY_EVERY = 4        # delta mode: every 4th layer dirty (async fine-tune)
DEGRADE_BW = 0.25      # congested rows: train->infer bandwidth scale

OUT_DIR = os.environ.get(
    "BENCH_OUT", os.path.join(os.path.dirname(__file__), "out"))


def _params() -> List[ParamMeta]:
    # one flat MeshGroup-style param per layer — the schedule granularity
    # at which the paper's pipeline moves tensors
    per = int(TOTAL_PARAMS / N_PARAMS)
    return [ParamMeta(f"w{i}", (per,), 2) for i in range(N_PARAMS)]


def _routes(changed: Optional[List[str]] = None):
    return compute_routing(_params(), N_TRAIN, N_INFER, infer_tp=INFER_TP,
                           quant_ratio=QUANT, changed=changed)


def synthetic_cluster(n_train: int, n_infer: int, nic: str = "efa",
                      infer_nic: Optional[str] = None):
    fab = Fabric(seed=0)
    te = [fab.add_engine(f"t{i}", nic=nic) for i in range(n_train)]
    ie = [fab.add_engine(f"i{i}", nic=infer_nic or nic)
          for i in range(n_infer)]
    descs = []
    for e in ie:
        buf = np.zeros(1, np.uint8)
        _, d = e.reg_mr(buf)
        descs.append(d)
    return fab, te, ie, descs


def p2p_synthetic(nic: str = "efa", changed: Optional[List[str]] = None,
                  chunk_bytes=None,
                  infer_nic: Optional[str] = None,
                  trace_path: Optional[str] = None,
                  degrade_bw: Optional[float] = None) -> Dict[str, float]:
    """The staged §5.2 pipeline over synthetic writes: chunked staging under
    the watermark, one WrBatch per pipeline window, two-phase commit.  Each
    FSDP source range is H2D'd + prepared ONCE and WRITTEN to every TP
    replica (16x wire amplification — exactly why the paper needs
    full-cluster bisection).  ``chunk_bytes`` defaults to the per-pair
    autotuned sweet spot (post/enqueue cost model, ROADMAP item); pass
    ``"online"`` to start at that value and let the
    :class:`OnlineChunkTuner` re-derive it mid-update from the always-on
    HealthMonitor's measured post/wire costs (online rows defer gate
    arming to commit time, since merges change the data-WRITE counts).
    ``infer_nic`` puts the inference cluster on a different NIC kind — the
    Holmes cross-zone shape; writes then ride the derived cross-fabric
    pair spec and the autotune uses its cost model.  ``degrade_bw``
    injects congestion: every train->infer channel's bandwidth is scaled
    by it before the update starts (the scenario the online tuner is
    for)."""
    routes, _sizes = _routes(changed)
    online = chunk_bytes == "online"
    if chunk_bytes is None or online:
        chunk_bytes = resolve_chunk_bytes(
            "auto", routes, nic, watermark_bytes=WATERMARK,
            stage_scale=STAGE_SCALE, dst_nic=infer_nic)
    fab, te, ie, descs = synthetic_cluster(N_TRAIN, N_INFER, nic,
                                           infer_nic=infer_nic)
    # attach before launch: RankPipeline captures fabric.tracer at build time
    tracer = maybe_tracer(fab) if trace_path else None
    monitor = attach_health(fab)
    if degrade_bw is not None:
        for i in range(N_TRAIN):
            for j in range(N_INFER):
                fab.degrade_pair(f"t{i}", f"i{j}", bw_scale=degrade_bw)
    chunks_by_rank = plan_chunks(routes, chunk_bytes=chunk_bytes,
                                 watermark_bytes=WATERMARK,
                                 stage_scale=STAGE_SCALE)

    if online:
        # deferred arming: the tuner may merge queued chunks mid-update, so
        # per-gate data-WRITE counts are only final at commit time
        gates = [CommitGate(eng) for eng in ie]
        n_data_live = [0] * len(ie)
    else:
        gates = arm_commit_gates(ie, chunks_by_rank, 0)

    def make_submit(rank, pipe):
        eng = te[rank]

        def submit(window):
            entries = []
            for c in window:
                left = {"n": len(c.targets)}

                def done(c=c, left=left):
                    left["n"] -= 1
                    if left["n"] == 0:
                        pipe.chunk_done_cb(c)

                for ir, _doff in c.targets:
                    if online:
                        n_data_live[ir] += 1
                    entries.append((c.nbytes, data_imm(0), descs[ir], done))
            eng.submit_synthetic_batch(entries)

        return submit

    def commit_fn():
        if online:
            for ir, g in enumerate(gates):
                g.arm(0, n_data_live[ir])
        te[0].submit_barrier(descs, commit_imm(0))

    tuners: Dict[int, OnlineChunkTuner] = {}
    tuner_factory = None
    if online:
        cap = max(MIN_CHUNK_BYTES, int(WATERMARK / STAGE_SCALE / 2))

        def tuner_factory(rank, pipe):
            t = OnlineChunkTuner(fab, te[rank].address(0), chunk_bytes,
                                 cap=cap)
            tuners[rank] = t
            return t

    stats = run_pipelined_update(
        fab, chunks_by_rank, make_submit=make_submit, commit_fn=commit_fn,
        watermark_bytes=WATERMARK, window_us=2.0, h2d=True,
        h2d_gbps=H2D_GBPS, prep_gbps=PREP_GBPS, tuner_factory=tuner_factory)
    out = {k: v for k, v in stats.items()}
    out["total_ms"] = stats["total_us"] * 1e-3
    out["h2d_ms"] = stats["h2d_us"] * 1e-3
    out["prep_ms"] = stats["prep_us"] * 1e-3
    out["chunk_bytes"] = chunk_bytes
    if online:
        out["chunk_bytes_final"] = max(
            (t.target for t in tuners.values()), default=chunk_bytes)
    out["committed"] = all(len(g.flips) == 1 for g in gates)
    for g in gates:
        g.audit_commits(0)
    out["commit_anomalies"] = sum(len(g.anomalies) for g in gates)
    out["health_flags"] = len(monitor.flags)
    if degrade_bw is None:
        assert_no_flags(monitor, f"p2p_synthetic({nic})")
    out.update(schedule_stats(routes, N_TRAIN, N_INFER,
                              full_routes=_routes()[0] if changed else None))
    if tracer is not None:
        out["trace_metrics"] = finish_trace(tracer, OUT_DIR, trace_path)
    return out


def p2p_synthetic_prepr(nic: str = "efa") -> Dict[str, float]:
    """The pre-PR path, kept for in-bench before/after: one
    ``submit_synthetic_write`` per route at whole-(rank, param) prepare
    granularity, no watermark, no batching, no commit."""
    routes, _ = _routes()
    fab, te, ie, descs = synthetic_cluster(N_TRAIN, N_INFER, nic)
    by_rank_param: Dict[int, Dict[str, List]] = {}
    for r in routes:
        by_rank_param.setdefault(r.train_rank, {}).setdefault(r.param, []).append(r)
    stats = {"h2d_ms": 0.0, "prep_ms": 0.0, "writes": 0}
    n_rep = N_INFER // INFER_TP
    for rank, per_param in by_rank_param.items():
        t_h2d = t_prep = 0.0
        for pname, rs in per_param.items():
            shard_in = 2 * sum(r.nbytes for r in rs) // n_rep   # bf16 shard
            t_h2d += (shard_in / H2D_GBPS) * 1e-3
            t_prep = max(t_prep, t_h2d) + (shard_in / PREP_GBPS) * 1e-3
            for r in rs:
                fab.loop.schedule(t_prep, lambda r=r, rank=rank:
                                  te[rank].submit_synthetic_write(
                                      r.nbytes, None, descs[r.infer_rank]))
                stats["writes"] += 1
        stats["h2d_ms"] = max(stats["h2d_ms"], t_h2d * 1e-3)
        stats["prep_ms"] = max(stats["prep_ms"], t_prep * 1e-3)
    t = fab.run()
    stats["total_ms"] = t * 1e-3
    return stats


def rank0_synthetic(nic: str = "efa") -> Dict[str, float]:
    """Rank0 gather+broadcast with the SAME two-phase commit as the p2p
    path (protocol parity for the Table-5 comparison): broadcast WRITEs
    carry the data immediate, one commit barrier follows, and every
    inference rank's CommitGate must flip exactly once."""
    routes, _ = _routes()
    fab, te, ie, descs = synthetic_cluster(N_TRAIN, N_INFER, nic)
    monitor = attach_health(fab)
    buf = np.zeros(1, np.uint8)
    _, d0 = te[0].reg_mr(buf)
    shard = int(TOTAL_PARAMS * 2 / N_TRAIN)
    for i in range(1, N_TRAIN):
        te[i].submit_synthetic_write(shard, None, d0)
    fab.run()
    t_gather = fab.now
    # rank0 broadcasts each inference rank's fp8 shard (TP=8, EP-style 1/16)
    gates = []
    for eng in ie:
        gate = CommitGate(eng)
        gate.arm(0, 1)
        gates.append(gate)
    out_bytes = int(TOTAL_PARAMS * 2 * QUANT)  # fp8
    left = {"n": N_INFER}

    def sent() -> None:
        left["n"] -= 1
        if left["n"] == 0:
            te[0].submit_barrier(descs, commit_imm(0))

    for r in range(N_INFER):
        te[0].submit_synthetic_write(out_bytes // (2 * INFER_TP),
                                     data_imm(0), descs[r], on_done=sent)
    t = fab.run()
    assert_no_flags(monitor, f"rank0_synthetic({nic})")
    return {"gather_ms": t_gather * 1e-3, "total_ms": t * 1e-3,
            "committed": all(len(g.flips) == 1 for g in gates)}


def run(report) -> None:
    from repro.core.transport import Channel
    prev = Channel.MAX_CHUNKS
    Channel.MAX_CHUNKS = 2   # timing is chunk-count-invariant; cut event load
    try:
        _run_inner(report)
    finally:
        Channel.MAX_CHUNKS = prev


def _run_inner(report) -> None:
    dirty = [f"w{i}" for i in range(0, N_PARAMS, DIRTY_EVERY)]
    summary: Dict[str, Dict] = {}
    trace_metrics = None

    for nic in ("efa", "cx7"):
        suffix = "" if nic == "efa" else f"_{nic}"
        # the canonical traced row: the full EFA p2p update (Table 5 anchor)
        tp = "trace_rlweights.json" if TRACE and nic == "efa" else None
        p2p = p2p_synthetic(nic, trace_path=tp)
        if tp and p2p.get("trace_metrics"):
            trace_metrics = p2p.pop("trace_metrics")
        summary[f"p2p{suffix or '_efa'}"] = p2p
        report(f"rl_p2p_total{suffix}", p2p["total_ms"] * 1e3,
               f"us = {p2p['total_ms']:.0f}ms total (paper 1233ms on efa), "
               f"h2d {p2p['h2d_ms']:.0f}ms (paper 184), "
               f"prep {p2p['prep_ms']:.0f}ms (paper 518+88), "
               f"{p2p['writes']} writes / {p2p['n_batches']} enqueues, "
               f"peak staged {p2p['peak_staged_bytes'] / (1 << 30):.2f}GiB "
               f"(wm {WATERMARK / (1 << 30):.0f}GiB), "
               f"committed={p2p['committed']}")

        # per-NIC chunk autotune (ROADMAP): the post/enqueue cost model
        # picks a different sweet spot per NIC; static 32MiB for compare
        static = p2p_synthetic(nic, chunk_bytes=CHUNK)
        summary[f"p2p_static_chunk{suffix or '_efa'}"] = static
        report(f"rl_chunk_autotune{suffix}", p2p["chunk_bytes"] / (1 << 20),
               f"MiB/chunk autotuned ({p2p['total_ms']:.0f}ms) vs "
               f"{CHUNK / (1 << 20):.0f}MiB static ({static['total_ms']:.0f}ms); "
               f"sweet spots differ per NIC (EFA per-WR cost ~7x CX7)")

        if nic == "efa":
            # closed-loop calibration rows (ISSUE 8): online must track the
            # static autotune on a clean fabric (hysteresis holds, schedule
            # stays ~byte-identical) and beat it once every train->infer
            # channel is degraded to 25% bandwidth — the measured per-WR
            # post cost then explodes past the spec and the tuner merges
            # the queued tail into bigger chunks mid-update.
            online = p2p_synthetic(nic, chunk_bytes="online")
            online["matches_auto"] = (
                abs(online["total_ms"] - p2p["total_ms"])
                <= 0.02 * p2p["total_ms"])
            summary["p2p_online_efa"] = online
            report("rl_online_clean", online["total_ms"] * 1e3,
                   f"us = {online['total_ms']:.0f}ms online-calibrated vs "
                   f"{p2p['total_ms']:.0f}ms static auto (clean fabric, "
                   f"{online['n_retunes']} retunes / "
                   f"{online['n_merges']} merges, "
                   f"matches_auto={online['matches_auto']})")

            cong_auto = p2p_synthetic(nic, degrade_bw=DEGRADE_BW)
            summary["p2p_auto_congested_efa"] = cong_auto
            cong_online = p2p_synthetic(nic, chunk_bytes="online",
                                        degrade_bw=DEGRADE_BW)
            cong_online["beats_auto_congested"] = (
                cong_online["total_ms"] < cong_auto["total_ms"])
            summary["p2p_online_congested_efa"] = cong_online
            report("rl_online_congested", cong_online["total_ms"] * 1e3,
                   f"us = {cong_online['total_ms']:.0f}ms online vs "
                   f"{cong_auto['total_ms']:.0f}ms static auto at "
                   f"{DEGRADE_BW:.2f}x bandwidth; "
                   f"{cong_online['n_retunes']} retunes merged "
                   f"{cong_online['n_merges']} chunks "
                   f"({cong_online['writes']} vs {cong_auto['writes']} "
                   f"writes, final chunk "
                   f"{cong_online['chunk_bytes_final'] / (1 << 20):.0f}MiB "
                   f"from {cong_online['chunk_bytes'] / (1 << 20):.0f}MiB), "
                   f"beats_auto={cong_online['beats_auto_congested']}, "
                   f"{cong_online['health_flags']} channels flagged")

        delta = p2p_synthetic(nic, changed=dirty)
        summary[f"p2p_delta{suffix or '_efa'}"] = delta
        report(f"rl_p2p_delta{suffix}", delta["total_ms"] * 1e3,
               f"us = {delta['total_ms']:.0f}ms for "
               f"{len(dirty)}/{N_PARAMS} dirty layers "
               f"({delta['delta_frac'] * 100:.0f}% of full bytes), "
               f"{delta['writes']} writes, committed={delta['committed']}")

        r0 = rank0_synthetic(nic)
        summary[f"rank0{suffix or '_efa'}"] = r0
        report(f"rl_rank0_total{suffix}", r0["total_ms"] * 1e3,
               f"us = {r0['total_ms'] / 1e3:.1f}s total (paper: 10-100s for "
               f"existing frameworks); committed={r0['committed']} "
               f"(same two-phase protocol); p2p speedup "
               f"{r0['total_ms'] / p2p['total_ms']:.0f}x")

    # Holmes cross-zone shape: CX7 training cluster -> EFA inference
    # cluster in one fabric; every train->infer pair rides the derived
    # x:cx7+efa200 cost model (bottleneck bw, summed latency, SRD jitter)
    mixed = p2p_synthetic("cx7", infer_nic="efa")
    summary["p2p_mixed_cx7_efa"] = mixed
    report("rl_p2p_total_mixed_cx7_efa", mixed["total_ms"] * 1e3,
           f"us = {mixed['total_ms']:.0f}ms total, CX7 train -> EFA infer "
           f"(cross-fabric pair spec; chunk "
           f"{mixed['chunk_bytes'] / (1 << 20):.1f}MiB from the pair cost "
           f"model), committed={mixed['committed']}")

    if os.environ.get("BENCH_RL_COMPARE") == "1":
        pre = p2p_synthetic_prepr("efa")
        summary["p2p_prepr_efa"] = pre
        report("rl_p2p_prepr", pre["total_ms"] * 1e3,
               f"us = {pre['total_ms']:.0f}ms pre-PR per-route path "
               f"({pre['writes']} writes, no watermark/batching/commit)")

    os.makedirs(OUT_DIR, exist_ok=True)
    doc = {
        "bench": "rlweights",
        "smoke": SMOKE,
        "config": {"n_train": N_TRAIN, "n_infer": N_INFER,
                   "infer_tp": INFER_TP, "n_params": N_PARAMS,
                   "total_params": TOTAL_PARAMS, "quant_ratio": QUANT,
                   "watermark_bytes": WATERMARK,
                   "static_chunk_bytes": CHUNK,
                   "chunk_bytes": "auto (per-NIC cost model)",
                   "dirty_every": DIRTY_EVERY,
                   "degrade_bw_congested": DEGRADE_BW},
        "paper_ms": {"p2p": 1233, "rank0_low": 10_000, "rank0_high": 100_000},
        "rows": {k: {kk: vv for kk, vv in v.items()
                     if isinstance(vv, (int, float, bool))}
                 for k, v in summary.items()},
        "speedup_p2p_vs_rank0_efa":
            summary["rank0_efa"]["total_ms"] / summary["p2p_efa"]["total_ms"],
        "delta_frac": summary["p2p_delta_efa"].get("delta_frac"),
    }
    if trace_metrics is not None:
        doc["metrics"] = trace_metrics
    with open(os.path.join(OUT_DIR, "BENCH_rlweights.json"), "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
