"""Table 3 / Table 4: KvCache transfer overlap and UvmWatcher latency.

Table 3 analog: per-layer paged KV transfer time on 2x200G EFA for
Qwen3-235B-class geometry (page 32 kB = 128 tokens), against the paper's
measured per-layer COMPUTE times — the claim being reproduced is that
layer-by-layer transfer hides under compute.  Table 4 analog: UvmWatcher
callback latency distribution under polling jitter.

`kvlayout_*` rows track the schema/plan path per architecture: full
reduced-cache state transfer through a compiled ``TransferPlan`` (one
WrBatch per layer span) for the uniform fast path (stablelm) and the
non-uniform schemas (gemma3 pattern-split rings, mamba2 SSM blobs), so
layout overhead vs the uniform path is visible per PR in the CI CSVs.
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.core import Fabric, Pages, UvmWatcher

from .obs_hooks import (TRACE, assert_no_flags, attach_health,
                        finish_trace, maybe_tracer)

OUT_DIR = os.environ.get(
    "BENCH_OUT", os.path.join(os.path.dirname(__file__), "out"))

# paper Table 3: seq_len -> (per-layer compute ms, paper transfer ms, pages)
PAPER_T3 = {4096: (2.267, 0.661, 256), 8192: (4.578, 0.952, 512),
            16384: (9.860, 1.610, 1024), 32768: (13.295, 1.606, 1024),
            65536: (20.344, 1.611, 1024), 131072: (34.895, 1.609, 1024)}
PAGE_BYTES = 32 << 10


def bench_layer_transfer(n_pages: int, nic: str = "efa", trace_path=None,
                         metrics_out=None) -> float:
    """One layer's paged KV write: ms until all pages delivered."""
    fab = Fabric(seed=0)
    tracer = maybe_tracer(fab) if trace_path else None
    monitor = attach_health(fab)
    a = fab.add_engine("prefill", nic=nic)
    b = fab.add_engine("decode", nic=nic)
    src = np.zeros(n_pages * PAGE_BYTES, np.uint8)
    dst = np.zeros(n_pages * PAGE_BYTES, np.uint8)
    hs, _ = a.reg_mr(src)
    _, dd = b.reg_mr(dst)
    done = []
    b.expect_imm_count(1, n_pages, lambda: done.append(fab.now))
    idx = tuple(range(n_pages))
    a.submit_paged_writes(PAGE_BYTES, 1, (hs, Pages(idx, PAGE_BYTES)),
                          (dd, Pages(idx, PAGE_BYTES)))
    fab.run()
    assert_no_flags(monitor, f"bench_layer_transfer({n_pages}, {nic})")
    if tracer is not None and metrics_out is not None:
        metrics_out["metrics"] = finish_trace(tracer, OUT_DIR, trace_path)
    return done[0] * 1e-3   # ms


def bench_uvm_latency(n: int = 2000) -> dict:
    """UvmWatcher store->callback latency percentiles (us)."""
    fab = Fabric(seed=1)
    lat = []
    e = fab.add_engine("n0", nic="efa")
    state = {}

    def cb(old, new):
        lat.append(fab.now - state["t"])

    w = e.alloc_uvm_watcher(cb)
    rng = np.random.default_rng(0)
    t = 0.0
    for i in range(n):
        t += float(rng.uniform(5.0, 50.0))
        fab.loop.schedule_at(t, lambda i=i: (state.__setitem__("t", fab.now),
                                             w.store(i + 1)))
    fab.run()
    a = np.asarray(lat)
    return {"avg": a.mean(), "p50": np.percentile(a, 50),
            "p99": np.percentile(a, 99), "max": a.max()}


def bench_schema_transfer(arch: str, seq_len: int = 256,
                          nic: str = "efa") -> dict:
    """Full reduced-cache state transfer via a compiled TransferPlan.

    Stages a synthetic cache of the arch's exact schema geometry, then
    submits one span per model layer (worst-case fragmentation) — returns
    simulated transfer time plus the plan/batch shape, so non-uniform
    layout overhead is comparable against the uniform fast path.
    """
    from repro.configs import get_config
    from repro.kvlayout import TransferPlan, schema_from_config
    from repro.serving import KvPool

    cfg = get_config(arch).reduced()
    schema = schema_from_config(cfg)
    plan = TransferPlan(schema, seq_len)

    fab = Fabric(seed=0)
    monitor = attach_health(fab)
    a = fab.add_engine("prefill", nic=nic)
    b = fab.add_engine("decode", nic=nic)
    pool_a = KvPool(a, schema, plan.n_slots)
    pool_b = KvPool(b, schema, plan.n_slots)
    src = pool_a.alloc(plan.n_slots)
    dst = pool_b.alloc(plan.n_slots)
    rng = np.random.default_rng(1)
    pool_a.buf[:] = rng.integers(0, 255, pool_a.buf.size, dtype=np.uint8)
    done = []
    for off, count in plan.expected_counts():
        b.expect_imm_count(100 + off, count, lambda: done.append(fab.now))
    for l in range(cfg.n_layers):
        plan.submit_span(a, pool_a.handle, src, pool_b.desc, dst, 100,
                         l, l + 1)
    fab.run()
    assert_no_flags(monitor, f"bench_schema_transfer({arch})")
    return {
        "us": max(done), "writes": plan.total_writes,
        "bytes": schema.total_bytes(seq_len),
        "enqueues": a.batch_stats.batches,
        "components": len(schema.components),
    }


def run(report) -> None:
    rows = {}
    tr_out = {}
    for seq, (compute_ms, paper_ms, pages) in PAPER_T3.items():
        # 8k-seq (512-page) layer is the canonical traced row
        tp = "trace_kvcache.json" if TRACE and seq == 8192 else None
        ms = bench_layer_transfer(pages, trace_path=tp, metrics_out=tr_out)
        hidden = ms < compute_ms
        rows[f"kv_layer_{seq >> 10}k"] = {
            "transfer_ms": ms, "paper_ms": paper_ms,
            "compute_ms": compute_ms, "hidden": hidden}
        report(f"kv_layer_{seq >> 10}k", ms * 1e3,
               f"us/layer transfer (paper {paper_ms}ms, compute {compute_ms}ms,"
               f" hidden={hidden})")
        assert hidden, f"transfer not hidden by compute at seq {seq}"
    u = bench_uvm_latency()
    rows["uvm_callback"] = {k: float(v) for k, v in u.items()}
    report("uvm_callback", u["p50"],
           f"us p50 (avg {u['avg']:.1f}, p99 {u['p99']:.1f}; paper Rust "
           f"p50 6.2 p99 12.6)")
    # schema/plan path: uniform fast path vs non-uniform layouts
    base = None
    for arch in ("stablelm-3b", "gemma3-1b", "mamba2-780m"):
        r = bench_schema_transfer(arch)
        if base is None:
            base = r["us"]
        rows[f"kvlayout_{arch}"] = {
            "us": r["us"], "writes": r["writes"], "bytes": r["bytes"],
            "enqueues": r["enqueues"], "components": r["components"],
            "vs_uniform": r["us"] / base}
        report(f"kvlayout_{arch}", r["us"],
               f"us full-state transfer ({r['components']} comps, "
               f"{r['writes']} WRs / {r['enqueues']} enqueues, "
               f"{r['bytes'] >> 10} KiB, {r['us'] / base:.2f}x uniform)")

    os.makedirs(OUT_DIR, exist_ok=True)
    doc = {
        "bench": "kvcache",
        "config": {"page_bytes": PAGE_BYTES,
                   "seq_lens": sorted(PAPER_T3),
                   "uvm_samples": 2000,
                   "schema_archs": ["stablelm-3b", "gemma3-1b",
                                    "mamba2-780m"]},
        "rows": rows,
    }
    if tr_out.get("metrics") is not None:
        doc["metrics"] = tr_out["metrics"]
    with open(os.path.join(OUT_DIR, "BENCH_kvcache.json"), "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
